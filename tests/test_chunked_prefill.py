"""Left-aligned chunked prefill: the differential equivalence harness.

Chunked prefill (``engine.prefill_chunk_step`` / the gateway's
PREFILLING state) must be *invisible* in the logits: feeding a prompt in
W-token chunks against a resident cache has to reproduce the one-shot
prefill within 1e-5 — for every cache layout the serving stack supports.
Property tests drive prompt lengths x chunk sizes x tier mixes through
both the engine-level step and full gateway streams; fixed tests pin the
``attend_cache`` corners (chunk landing exactly on a block boundary,
single-token final chunk, ring/window snapshot path, int8 KV
requantization) and the preempt-mid-prefill recompute restart.

Reference notes (why not every config compares against ``prefill_step``):
  * fp linear / MLA / plain ring: one-shot ``prefill_step`` IS the
    reference — the chunked path must match it.
  * int8 KV: ``prefill_step`` attends the *raw* fp K/V of the chunk
    being written, while ``attend_cache`` attends what the cache will
    actually hold — the dequantized int8 round trip.  The faithful
    reference is a single whole-prompt ``attend_cache`` chunk (identical
    per-token quantization, so multi-chunk must match it exactly);
    against raw-fp prefill only a loose quantization-noise bound holds.
  * ring + int8 composes both, so the reference is the single-chunk
    ``attend_cache`` run as well.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                           # keep the module collectable
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.models import model as model_lib
from repro.serving import LicensedGateway, RequestState
from repro.serving.engine import (prefill_chunk_step, prefill_step,
                                  stack_lane_caches)

CAP = 16


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def mla():
    cfg = smoke_variant(get_config("deepseek-v2-lite-16b"))
    return cfg, init_params(jax.random.PRNGKey(1), cfg)


@pytest.fixture(scope="module")
def gemma():
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    assert cfg.window > 0                     # the ring/window path
    return cfg, init_params(jax.random.PRNGKey(2), cfg)


TIERS = {
    "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
    "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
}


def _prompt(seed, n):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


def _chunked_lane(params, cfg, prompt, chunk, capacity=CAP):
    """Prefill one lane in left-aligned ``chunk``-token pieces; returns
    the last real token's logits (what decode would condition on)."""
    caches = stack_lane_caches(cfg, 1, capacity)
    cur, n, last = 0, len(prompt), None
    while cur < n:
        v = min(chunk, n - cur)
        row = np.full((1, chunk), int(prompt[-1]), np.int32)
        row[0, :v] = prompt[cur:cur + v]
        logits, caches = prefill_chunk_step(
            params, cfg, jnp.asarray(row), caches,
            jnp.asarray([cur], np.int32),
            chunk_valid=jnp.asarray([v], np.int32))
        last = np.asarray(logits)[0, v - 1]
        cur += v
    return last


def _one_shot(params, cfg, prompt, capacity=CAP):
    cache = model_lib.init_cache(cfg, 1, capacity)
    logits, _ = prefill_step(params, cfg, jnp.asarray(prompt)[None], cache)
    return np.asarray(logits)[0]


# ----------------------------------------------------- engine differential
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 12), chunk=st.sampled_from([1, 2, 3, 4, 5, 8]))
def test_chunked_matches_one_shot_prefill(qwen, n, chunk):
    """Property: any (prompt length, chunk size) reproduces the one-shot
    last-token logits on the linear GQA cache."""
    cfg, params = qwen
    p = _prompt(31 * n + chunk, n)
    got = _chunked_lane(params, cfg, p, chunk)
    want = _one_shot(params, cfg, p)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("n,chunk", [
    (12, 4),   # final chunk lands exactly on a chunk/block boundary
    (9, 4),    # single-token final chunk
    (4, 4),    # whole prompt in one chunk (degenerate == one-shot)
    (7, 16),   # chunk wider than the prompt (gateway clamp case)
])
def test_attend_cache_boundary_edges(qwen, n, chunk):
    """The ``attend_cache`` write-offset edges: exact-boundary chunks,
    a 1-token tail, and a chunk wider than the remaining prompt."""
    cfg, params = qwen
    p = _prompt(100 + n, n)
    got = _chunked_lane(params, cfg, p, chunk)
    want = _one_shot(params, cfg, p)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("n,chunk", [(12, 4), (9, 4), (11, 5)])
def test_chunked_matches_one_shot_mla(mla, n, chunk):
    """MLA's compressed c_kv/k_rope cache chunk-prefills to the same
    logits as its one-shot prefill."""
    cfg, params = mla
    p = _prompt(200 + n, n)
    got = _chunked_lane(params, cfg, p, chunk)
    want = _one_shot(params, cfg, p)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("n,chunk", [(40, 8), (40, 7), (33, 32), (34, 5)])
def test_chunked_matches_one_shot_window(gemma, n, chunk):
    """Ring (sliding-window) caches: chunked prefill past the window
    wraps the ring via the snapshot-attend path and must still match the
    legacy whole-sequence windowed prefill."""
    cfg, params = gemma
    assert n > cfg.window                     # the ring actually wraps
    p = _prompt(300 + n + chunk, n)
    got = _chunked_lane(params, cfg, p, chunk, capacity=48)
    want = _one_shot(params, cfg, p, capacity=48)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("n,chunk", [(12, 4), (9, 4), (11, 5)])
def test_chunked_int8_kv_matches_one_shot_attend(qwen, n, chunk):
    """int8 KV: chunk boundaries must not change what gets quantized —
    multi-chunk equals the single-chunk ``attend_cache`` run exactly
    (same per-token scales), and sits within quantization noise of the
    raw-fp one-shot."""
    cfg, params = qwen
    cfg = cfg.replace(kv_cache_int8=True)
    p = _prompt(400 + n, n)
    got = _chunked_lane(params, cfg, p, chunk)
    want = _chunked_lane(params, cfg, p, len(p))   # one-shot attend_cache
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)
    raw = _one_shot(params, cfg, p)
    scale = max(1e-3, float(np.abs(raw).max()))
    assert float(np.abs(got - raw).max()) / scale < 0.2


def test_chunked_window_int8_self_consistent(gemma):
    """ring + int8 composed: no raw-fp one-shot reference exists (the
    legacy path quantizes only the retained window), so pin cross-chunk-
    size self-consistency instead."""
    cfg, params = gemma
    cfg = cfg.replace(kv_cache_int8=True)
    p = _prompt(500, 40)
    a = _chunked_lane(params, cfg, p, 5, capacity=48)
    b = _chunked_lane(params, cfg, p, 9, capacity=48)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=0)


def test_multilane_mixed_lengths_vmapped(qwen):
    """One ``prefill_chunk_step`` batch with per-lane cursors: lanes of
    different lengths advance together (finished lanes harmlessly refeed
    their final token, as the gateway's pad rows do) and each lane's
    completion logits match its own one-shot prefill."""
    cfg, params = qwen
    lens, chunk, b = [5, 9, 12], 4, 3
    prompts = [_prompt(600 + i, n) for i, n in enumerate(lens)]
    caches = stack_lane_caches(cfg, b, CAP)
    cursors = [0] * b
    final = [None] * b
    while any(c < n for c, n in zip(cursors, lens)):
        rows = np.zeros((b, chunk), np.int32)
        poss = np.zeros(b, np.int32)
        valid = np.zeros(b, np.int32)
        for i in range(b):
            if cursors[i] < lens[i]:
                start, v = cursors[i], min(chunk, lens[i] - cursors[i])
            else:                             # done: rewrite the last token
                start, v = lens[i] - 1, 1
            valid[i] = v
            rows[i, :] = int(prompts[i][-1])
            rows[i, :v] = prompts[i][start:start + v]
            poss[i] = start
        logits, caches = prefill_chunk_step(
            params, cfg, jnp.asarray(rows), caches,
            jnp.asarray(poss), chunk_valid=jnp.asarray(valid))
        logits = np.asarray(logits)
        for i in range(b):
            if cursors[i] < lens[i]:
                cursors[i] += int(valid[i])
                if cursors[i] == lens[i]:
                    final[i] = logits[i, valid[i] - 1]
    for i in range(b):
        want = _one_shot(params, cfg, prompts[i])
        np.testing.assert_allclose(final[i], want, atol=1e-5, rtol=0)


# ---------------------------------------------------- gateway differential
def _gateway(setup, **kw):
    cfg, params = setup
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_prompt", 12)
    kw.setdefault("max_new_cap", 6)
    kw.setdefault("block_size", 4)
    kw.setdefault("record_logits", True)
    return LicensedGateway(cfg, params, tiers=TIERS, **kw)


def _drain(gw, work, max_new=3):
    reqs = [gw.submit(p, license=t, max_new_tokens=max_new) for p, t in work]
    gw.run()
    assert all(r.state == RequestState.DONE for r in reqs), \
        [r.error for r in reqs]
    return reqs


def _truth_stream(gw, prompt, tier, max_new):
    """Greedy ground truth: full re-forward of the TRUE prompt (+ the
    tokens generated so far) through the request's licensed view."""
    view, li = gw.views.get(tier, gw.version)
    toks, out, rows = list(int(t) for t in prompt), [], []
    for _ in range(max_new):
        logits, _, _ = model_lib.forward(
            view, gw.cfg, jnp.asarray(toks, jnp.int32)[None, :],
            license_intervals=li)
        row = np.asarray(logits[0, -1])
        rows.append(row)
        out.append(int(row.argmax()))
        toks.append(out[-1])
    return out, rows


@settings(max_examples=6, deadline=None)
@given(spec=st.lists(st.tuples(st.integers(2, 12),
                               st.sampled_from(["free", "pro", "full"])),
                     min_size=2, max_size=5),
       chunk=st.sampled_from([1, 3, 4, 8]),
       seed=st.integers(0, 10_000))
def test_gateway_chunked_matches_true_prompt_stream(qwen, spec, chunk, seed):
    """Property: a mixed-length, mixed-tier stream served by the chunked
    gateway produces the TRUE prompt's greedy tokens and per-step logits
    within 1e-5 — for every prompt length, not just full-width ones.

    (The legacy bucket path is NOT the reference here: it serves the
    prompt right-padded to ``max_prompt``, so for short prompts its
    logits are conditioned on junk pad tokens.  Chunked prefill serving
    the true tokens is the fix, verified against a from-scratch forward.)
    """
    work = [(_prompt(seed + i, n), t) for i, (n, t) in enumerate(spec)]
    gw = _gateway(qwen, chunk_size=chunk)
    reqs = _drain(gw, work)
    for (p, tier), r in zip(work, reqs):
        toks, rows = _truth_stream(gw, p, tier, len(r.out_tokens))
        assert r.out_tokens == toks
        for la, lb in zip(r.logits_rows, rows):
            np.testing.assert_allclose(la, lb, atol=1e-5, rtol=0)


def test_gateway_chunked_matches_legacy_at_full_width(qwen):
    """At full ``max_prompt`` width the legacy bucket path serves the
    true tokens too, so chunked and legacy streams must coincide — and
    both must equal the ground-truth greedy stream (runs without
    hypothesis, so the gateway differential is always exercised)."""
    work = [(_prompt(40 + i, 12), t)
            for i, t in enumerate(["free", "pro", "free"])]
    for chunk in (1, 4, 8):
        gw = _gateway(qwen, chunk_size=chunk)
        a = _drain(gw, work)
        b = _drain(_gateway(qwen, chunk_size=0), work)
        for (p, tier), ra, rb in zip(work, a, b):
            assert ra.out_tokens == rb.out_tokens
            toks, rows = _truth_stream(gw, p, tier, len(ra.out_tokens))
            assert ra.out_tokens == toks
            for la, lb, lt in zip(ra.logits_rows, rb.logits_rows, rows):
                np.testing.assert_allclose(la, lb, atol=1e-5, rtol=0)
                np.testing.assert_allclose(la, lt, atol=1e-5, rtol=0)


def test_preempt_mid_prefill_restarts_equivalently(qwen):
    """A request preempted with its prompt half-chunked restarts from
    cursor 0 on re-admission and reproduces the uncontended tokens."""
    p = _prompt(7, 12)
    want = _drain(_gateway(qwen, chunk_size=4), [(p, "free")])[0]

    gw = _gateway(qwen, chunk_size=4)
    r = gw.submit(p, license="free", max_new_tokens=3)
    for _ in range(100):
        if r.state is RequestState.PREFILLING and 0 < r.cursor < len(p):
            break
        gw.step()
    assert r.state is RequestState.PREFILLING and 0 < r.cursor < len(p)
    gw._preempt(r)
    assert r.state is RequestState.QUEUED and r.cursor == 0
    assert gw.stats["preempted"] == 1
    gw.run()
    assert r.state == RequestState.DONE and r.preemptions == 1
    assert r.out_tokens == want.out_tokens
    for la, lb in zip(r.logits_rows, want.logits_rows):
        np.testing.assert_allclose(la, lb, atol=1e-5, rtol=0)
    assert gw.pool.allocator.num_held == 0 or gw.prefix is not None


# ------------------------------------------------ length-independent reuse
def test_prefix_reuse_across_prompt_lengths(qwen):
    """The radix cache keys on TRUE token ids: a second request sharing
    the system prompt but with a different-length user suffix hits the
    same chain — across what the legacy right-aligned keys treated as
    incompatible pad layouts — and block-aligned tails adopt with zero
    copy-on-write."""
    head = _prompt(800, 8)                    # 2 full blocks of 4
    a = np.concatenate([head, _prompt(801, 4)])    # len 12, aligned tail
    b = np.concatenate([head, _prompt(802, 8)])    # len 16 — other length

    gw = _gateway(qwen, max_prompt=16)
    assert gw.chunked and gw.chunk_size == 4
    _drain(gw, [(a, "free")], max_new=2)
    ra, = _drain(gw, [(b, "free")], max_new=2)
    assert ra.prefix_tokens == len(head)
    pm = gw.metrics()["prefix_cache"]
    assert pm["hits"] >= 1
    assert pm["prefix_tokens_reused"] >= len(head)
    assert pm["cow_copies"] == 0              # aligned tails: no CoW ever
    cm = gw.metrics()["chunked_prefill"]
    assert cm["enabled"] and cm["chunks"] >= 3 + 2

    # contrast: legacy right-aligned keys cannot match across lengths
    gw0 = _gateway(qwen, max_prompt=16, chunk_size=0)
    _drain(gw0, [(a, "free")], max_new=2)
    _drain(gw0, [(b, "free")], max_new=2)
    assert gw0.metrics()["prefix_cache"]["prefix_tokens_reused"] == 0


# --------------------------------------------------------- config gating
def test_chunk_size_gating(qwen, gemma):
    """Explicit ``chunk_size`` on unsupported layouts must refuse loudly;
    the window model silently falls back to legacy one-shot prefill."""
    cfg, params = qwen
    with pytest.raises(ValueError, match="paged"):
        LicensedGateway(cfg, params, tiers=TIERS, max_batch=2, max_prompt=8,
                        max_new_cap=4, paged=False, chunk_size=4)
    wcfg, wparams = gemma
    gw = LicensedGateway(wcfg, wparams, tiers=TIERS, max_batch=2,
                         max_prompt=8, max_new_cap=4, block_size=4)
    assert not gw.chunked
    assert gw.metrics()["chunked_prefill"]["enabled"] is False
    with pytest.raises(ValueError, match="chunk"):
        LicensedGateway(wcfg, wparams, tiers=TIERS, max_batch=2,
                        max_prompt=8, max_new_cap=4, block_size=4,
                        chunk_size=4)


# ------------------------------------------------------------ long context
@pytest.mark.long_context
@pytest.mark.slow
def test_long_prompt_chunks_interleave_with_decode(qwen):
    """A 4k-token prompt chunks through while short requests keep
    decoding: between consecutive chunk steps of the long prefill the
    scheduler always runs a decode step when decodes are runnable — the
    bounded-stall guarantee the SLO knob buys."""
    cfg, params = qwen
    n = 4096
    gw = LicensedGateway(cfg, params, tiers=TIERS, max_batch=2,
                         max_prompt=n, max_new_cap=64, block_size=64,
                         chunk_size=256, num_blocks=80, max_lanes=4)
    short = [gw.submit(_prompt(i, 32), license="free", max_new_tokens=48)
             for i in range(2)]
    gw.step()                                  # admit + first short chunk
    long = gw.submit(_prompt(99, n), license="free", max_new_tokens=2)
    kinds = []
    while gw.scheduler.running or gw.scheduler.waiting:
        act = gw.step()
        if act is None:
            break
        decodes_live = any(r.state is RequestState.RUNNING
                           for r in gw.scheduler.running)
        kinds.append((act.kind, decodes_live))
    assert long.state == RequestState.DONE
    assert all(r.state == RequestState.DONE for r in short)
    # no two consecutive prefill chunks while a decode lane was runnable
    for (k1, live1), (k2, _) in zip(kinds, kinds[1:]):
        assert not (k1 == "prefill" and k2 == "prefill" and live1), kinds
    assert gw.stats["prefill_chunks"] >= n // 256
