"""Observability layer: histogram/percentile math, Prometheus text
exposition, Chrome trace_event export + validation, request-lifecycle
spans through the gateway (preempt/restart included), the licensing
audit stream, injectable-clock plumbing, and the metrics()-schema lint
shared with the fleet (see tests/test_fleet.py for the fleet side)."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import (Histogram, LicensedGateway, RequestState,
                           Telemetry, TraceRecorder, validate_chrome_trace,
                           validate_gateway_metrics)
from repro.serving.tracing import AuditLog
from repro.analysis.metrics import declared_match, unregistered_metric_keys

MAX_PROMPT = 8
MAX_NEW = 8


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    return cfg, params, tiers


def _gateway(setup, **kw):
    cfg, params, tiers = setup
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    return LicensedGateway(cfg, params, tiers=tiers, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


# ------------------------------------------------------------- instruments
def test_histogram_buckets_and_percentiles():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 3.0, 6.0, 20.0):
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(31.0)
    assert h.counts == [1, 1, 1, 1, 1]        # one per bucket + one +Inf
    # rank 2.5 lands mid-way through the (2, 4] bucket
    assert h.p50 == pytest.approx(3.0)
    # the +Inf bucket reports the last finite edge, never infinity
    assert h.percentile(100) == pytest.approx(8.0)
    assert h.summary() == {"count": 5, "sum": pytest.approx(31.0),
                           "p50": pytest.approx(3.0), "p90": h.p90,
                           "p99": h.p99}
    # exact edge counts as <= edge (Prometheus ``le`` semantics)
    h2 = Histogram("edge", buckets=(1.0, 2.0))
    h2.observe(2.0)
    assert h2.counts == [0, 1, 0]
    assert Histogram("empty").percentile(99) == 0.0
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))


def test_counter_gauge_pull_model():
    """fn-backed instruments read live state at export time — the
    hot path never touches them."""
    stats = {"n": 0}
    t = Telemetry()
    c = t.counter("reqs_total", fn=lambda: stats["n"])
    g = t.gauge("depth", fn=lambda: stats["n"] * 2)
    stats["n"] = 7
    assert c.value == 7 and g.value == 14
    assert t.counter("reqs_total") is c       # get-or-create, same key
    with pytest.raises(ValueError):
        t.gauge("reqs_total")                 # kind collision
    push = t.counter("errs_total")
    push.inc()
    push.inc(2)
    assert push.value == 3


def test_disabled_registry_histograms_are_noops():
    t = Telemetry(enabled=False)
    h = t.histogram("lat_s")
    h.observe(1.0)
    assert h.count == 0 and h.sum == 0.0


def test_prometheus_exposition():
    t = Telemetry()
    t.counter("served_total", labels={"model": "m1"}, help="reqs").inc(3)
    h = t.histogram("wait_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = t.render_prometheus()
    assert "# HELP served_total reqs" in text
    assert "# TYPE served_total counter" in text
    assert 'served_total{model="m1"} 3' in text
    assert "# TYPE wait_s histogram" in text
    # buckets are CUMULATIVE, +Inf equals the observation count
    assert 'wait_s_bucket{le="0.1"} 1' in text
    assert 'wait_s_bucket{le="1.0"} 2' in text
    assert 'wait_s_bucket{le="+Inf"} 3' in text
    assert "wait_s_count 3" in text
    assert "wait_s_sum 5.55" in text


def test_adopt_merges_and_rejects_collisions():
    a, b = Telemetry(), Telemetry()
    b.counter("x_total", labels={"model": "m2"})
    a.adopt(b)
    assert a.counter("x_total", labels={"model": "m2"}).value == 0
    a.adopt(a)                                 # self-adopt is a no-op
    c = Telemetry()
    c.counter("x_total", labels={"model": "m2"})
    with pytest.raises(ValueError):
        a.adopt(c)


# ------------------------------------------------------------ trace / audit
def test_trace_recorder_chrome_export():
    t = {"now": 0.0}

    def clk():
        t["now"] += 1.0
        return t["now"]

    rec = TraceRecorder(clock=clk)
    rec.begin("queue", rid=0)
    rec.instant("admit", rid=0, attrs={"tier": "free"})
    rec.end("queue", rid=0)
    rec.begin("decode", rid=0)                 # left open: auto-closed
    rec.counter("depth", 3)
    events = validate_chrome_trace(rec.chrome_trace())
    phases = [e["ph"] for e in events if e["ph"] != "M"]
    assert phases.count("B") == phases.count("E") == 2
    names = {e["name"] for e in events}
    assert {"queue", "admit", "decode", "depth"} <= names
    admit = next(e for e in events if e["name"] == "admit")
    assert admit["args"]["tier"] == "free"
    # a hand-built tape with an unclosed B must fail validation
    bad = json.dumps([{"ph": "B", "ts": 0, "pid": 1, "tid": 2, "name": "x"}])
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError):
        validate_chrome_trace("not json")


def test_audit_log_order_and_merge():
    t = {"now": 10.0}
    log = AuditLog(clock=lambda: t["now"])
    log.record("tier_grant", tier="free", model="m")
    log.record("version_flip", from_version=1, to_version=2)
    ev = log.events()
    assert [e["event"] for e in ev] == ["tier_grant", "version_flip"]
    assert ev[0]["seq"] == 0 and ev[1]["seq"] == 1
    assert log.events("version_flip")[0]["to_version"] == 2
    lines = log.render_jsonl().strip().splitlines()
    assert len(lines) == 2 and json.loads(lines[0])["event"] == "tier_grant"
    other = AuditLog(clock=lambda: 5.0)
    other.record("sync_begin", model="m")
    merged = AuditLog.merge([log, other])
    assert [e["event"] for e in merged] == \
        ["sync_begin", "tier_grant", "version_flip"]


# ------------------------------------------------------- gateway lifecycle
def test_gateway_trace_metrics_audit_roundtrip(setup, tmp_path):
    gw = _gateway(setup)
    reqs = [gw.submit(_prompt(i), license="free" if i % 2 else "full",
                      max_new_tokens=3) for i in range(3)]
    gw.submit(_prompt(9, n=50), license="full")          # rejected
    gw.run()
    assert all(r.state == RequestState.DONE for r in reqs)

    m = gw.metrics()
    validate_gateway_metrics(m)
    # lint: every metrics() key is registered in the telemetry registry
    assert unregistered_metric_keys(m, gw.telemetry.declared) == []

    # lifecycle spans, in order, on a completed request
    names = gw.tracer.span_names(reqs[0].rid)
    for span in ("submit", "queue", "admit", "prefill", "decode", "finish"):
        assert span in names, f"missing {span} in {names}"
    assert names.index("queue") < names.index("prefill") < \
        names.index("decode")

    # the whole-gateway tape is a valid Chrome trace: parseable JSON
    # array, monotonic per-track timestamps, matched B/E pairs
    path = tmp_path / "trace.json"
    path.write_text(gw.chrome_trace())
    events = validate_chrome_trace(path.read_text())
    assert isinstance(json.loads(path.read_text()), list)
    assert any(e["name"].startswith("sched:") for e in events)
    assert any(e["ph"] == "C" for e in events)           # counter tracks

    # latency histograms: TTFT once per request, gaps between the rest
    assert gw.h_ttft.count == 3
    assert gw.h_gap.count == m["tokens_generated"] - 3
    assert gw.h_queue.count == 3
    assert m["latency"]["ttft_s"]["count"] == 3

    text = gw.render_prometheus()
    assert "serving_ttft_seconds_bucket" in text
    assert "serving_requests_admitted_total" in text

    # audit stream: tier grants at boot, view materializations on use,
    # and the rejection left a trace instant, not an audit entry
    audit = {e["event"] for e in gw.audit_events()}
    assert {"tier_grant", "view_materialize"} <= audit
    assert "reject" in {e["name"] for e in events}


def test_telemetry_off_leaves_no_wake(setup):
    """telemetry=False: no spans, no histogram observes, no audit —
    the benchmark baseline the <3% overhead gate compares against."""
    gw = _gateway(setup, telemetry=False)
    r = gw.submit(_prompt(0), license="free", max_new_tokens=3)
    gw.run()
    assert r.state == RequestState.DONE
    assert not gw.obs
    assert len(gw.tracer.events) == 0
    assert gw.h_ttft.count == 0 and gw.h_gap.count == 0
    assert gw.audit_events() == []
    validate_gateway_metrics(gw.metrics())    # schema holds either way


def test_injectable_clock_everywhere(setup):
    """Satellite fix: queue waits come from the injected clock — a
    frozen clock advanced by hand yields EXACT wait numbers, which
    direct time.monotonic()/perf_counter() calls could never produce."""
    t = {"now": 100.0}
    gw = _gateway(setup, clock=lambda: t["now"])
    gw.submit(_prompt(0), license="free", max_new_tokens=2)
    t["now"] = 103.5
    m = gw.metrics()
    assert m["oldest_wait_s"] == pytest.approx(3.5)
    assert m["queue_wait_by_tier"]["free"] == pytest.approx(3.5)
    gw.run()
    assert gw.h_queue.count == 1
    assert gw.h_queue.sum == pytest.approx(3.5)   # observed at admission
    # every trace timestamp came from the frozen clock
    assert all(ev[0] in (100.0, 103.5) for ev in gw.tracer.events)


def test_preempt_restart_spans_and_ttft_counted_once(setup):
    """A preempted-and-restarted request's trace shows the preempt and
    restart events, its spans still pair up, and TTFT/queue-wait land
    in the histograms exactly once despite the second admission."""
    gw = _gateway(setup, max_batch=2, paged=True, block_size=4,
                  prefix_cache=False, max_lanes=4, num_blocks=7)
    reqs = [gw.submit(_prompt(i), license="free",
                      max_new_tokens=3 + 2 * (i % 2)) for i in range(5)]
    gw.run()
    assert gw.stats["preempted"] > 0
    assert all(r.state == RequestState.DONE for r in reqs)

    victims = [r for r in reqs if r.preemptions]
    assert victims
    for r in victims:
        names = gw.tracer.span_names(r.rid)
        assert "preempt" in names and "restart" in names
        assert names.index("preempt") < names.index("restart")
        evs = gw.tracer.request_events(r.rid)
        assert sum(e["name"] == "preempt" for e in evs) == r.preemptions
    # B/E pairs survive mid-flight preemption on every track
    validate_chrome_trace(gw.chrome_trace())
    assert gw.h_ttft.count == len(reqs)       # once per request, ever
    assert gw.h_queue.count == len(reqs)      # first admission only


# --------------------------------------------------------- staged-sync audit
def test_staged_flip_emits_exactly_one_version_flip(setup):
    cfg, params, _ = setup
    params = jax.device_get(params)
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": ((0.0, 0.004),)}))
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    gw = LicensedGateway.from_server(cfg, server, "lm", template,
                                     max_batch=2, max_prompt=MAX_PROMPT,
                                     max_new_cap=16)
    a = gw.submit(_prompt(1), license="free", max_new_tokens=10)
    gw.step()                                 # a is mid-stream
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    assert gw.begin_sync(max_step_bytes=4 << 20) is True
    for _ in range(10_000):
        if not (gw.sync_active or gw.scheduler.waiting
                or gw.scheduler.running):
            break
        gw.step()
    assert a.state == RequestState.DONE and gw.version == 2

    flips = gw.audit_events("version_flip")
    assert len(flips) == 1                    # exactly one, at the flip
    assert flips[0]["from_version"] == 1 and flips[0]["to_version"] == 2
    assert len(gw.audit_events("sync_begin")) == 1
    assert gw.h_stager.count > 0              # phases were timed
    events = validate_chrome_trace(gw.chrome_trace())
    stager = {e["name"] for e in events if e["name"].startswith("stager:")}
    assert "stager:flip" in stager

    # the blocking path funnels through the same choke point: still one
    # flip event per version bump
    server.publish("lm", params, tag="v3")
    assert gw.sync() is True
    assert gw.version == 3
    assert len(gw.audit_events("version_flip")) == 2


# ------------------------------------------------------------- schema lint
def test_unregistered_keys_lint_flags_strays():
    # the schema primitives live in repro.analysis.metrics now; this
    # exercises them through a live Telemetry declaration set
    t = Telemetry()
    t.declare("known", "nested.*")
    assert unregistered_metric_keys(
        {"known": 1, "nested": {"a": 2, "b": 3}}, t.declared) == []
    assert unregistered_metric_keys({"stray": 1}, t.declared) == ["stray"]
    assert declared_match("nested.deep.leaf", t.declared)
    assert not declared_match("nested2", t.declared)
