"""Fallback for environments without ``hypothesis`` installed.

The property-based tests import ``given``/``settings``/``st`` through a
guarded import (see requirements-dev.txt for the real dependency).  When
hypothesis is missing, these stand-ins keep the module importable —
collection no longer fails — and each property test individually reports
SKIPPED while every plain pytest test in the same file still runs.
"""
import pytest


class _StrategyStub:
    """Accepts any ``st.<name>(...)`` call; the value is never used."""

    def __getattr__(self, name):
        def strategy(*args, **kwargs):
            return None

        return strategy


st = _StrategyStub()


def settings(*args, **kwargs):
    def deco(fn):
        return fn

    return deco


def given(*args, **kwargs):
    def deco(fn):
        # zero-arg wrapper: pytest must not mistake hypothesis-bound
        # parameters for fixtures
        def skipper():
            pytest.skip("hypothesis not installed")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco
