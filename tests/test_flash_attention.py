"""Flash-attention Pallas kernel vs materialized-softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain tests still run
    from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def mk(seed, bh, sq, sk, hd, bkh=None):
    r = np.random.default_rng(seed)
    bkh = bkh or bh
    q = jnp.asarray(r.standard_normal((bh, sq, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((bkh, sk, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((bkh, sk, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("sq,sk,bq,bk", [(256, 256, 128, 128), (512, 512, 256, 128),
                                          (256, 512, 128, 256)])
def test_flash_causal_matches_ref(sq, sk, bq, bk):
    q, k, v = mk(0, 4, sq, sk, 64)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_gqa_groups():
    """8 q heads share 2 kv heads via the index map (no kv replication)."""
    q, k, v = mk(1, 8, 256, 256, 64, bkh=2)
    got = flash_attention(q, k, v, causal=True, groups=4, block_q=128,
                          block_k=128, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, groups=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_sliding_window():
    q, k, v = mk(2, 2, 512, 512, 64)
    got = flash_attention(q, k, v, causal=True, window=128, block_q=128,
                          block_k=128, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_decode_offset():
    """Sq=block with a large q_offset == decode against a long context."""
    q, k, v = mk(3, 2, 128, 1024, 64)
    got = flash_attention(q, k, v, causal=True, q_offset=896, block_q=128,
                          block_k=256, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, q_offset=896)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_noncausal():
    q, k, v = mk(4, 2, 256, 256, 64)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       hd=st.sampled_from([64, 128]),
       sq=st.sampled_from([256, 512]))
def test_flash_property(seed, hd, sq):
    q, k, v = mk(seed, 2, sq, sq, hd)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)
