"""Staged weight sync + delta-protocol correctness regressions.

Covers the staged-update subsystem (``serving/updates.py``): bounded
stager steps, mid-stream token equivalence across a staged ``sync()``,
prewarmed views, the atomic weights+tiers flip — and the two
``_mask_packet`` wire-format regressions (chunk dtype, explicit
compression flags) — plus the background-fetch worker (wire transfer
off-thread, apply on the serving thread)."""
import threading
import zlib

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import delta as delta_lib
from repro.core.licensing import LicenseTier
from repro.core.protocol import EdgeClient, LicenseServer, _mask_packet
from repro.core.weightstore import LayerDelta, UpdatePacket, WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState

MAX_PROMPT = 8


# ---------------------------------------------------------------- wire format
@pytest.mark.parametrize("dtype", ["float32", "float16"])
def test_mask_packet_chunk_dtype(dtype):
    """Chunk pages must be decoded with the delta's dtype: masking a
    non-f32 layer used to reinterpret its pages as f32 and silently
    corrupt every shipped value."""
    store = WeightStore(":memory:", row_limit=8, chunk_elems=4)
    store.register_model("m", "mlp")
    server = LicenseServer(store)
    rng = np.random.default_rng(0)
    p = {"l1/kernel": rng.standard_normal((8, 4)).astype(dtype)}
    server.publish("m", p)
    server.publish_tier("m", LicenseTier(name="free",
                                         masks={"l1": ((0.5, 0.9),)}))

    client = EdgeClient("m", {"l1/kernel": np.zeros((8, 4), dtype)},
                        license_name="free")
    client.request_update(server)
    got = client.params["l1/kernel"]
    assert got.dtype == np.dtype(dtype)
    mag = np.abs(p["l1/kernel"].astype(np.float32))
    banned = (mag >= 0.5) & (mag < 0.9)
    assert banned.any()
    assert (np.asarray(got)[banned] == 0).all()
    np.testing.assert_array_equal(np.asarray(got)[~banned],
                                  p["l1/kernel"][~banned])


def _zlib_lookalike_page():
    """Raw float32 bytes that happen to be a complete, valid zlib stream."""
    for n in range(1, 4096):
        blob = zlib.compress(b"\x00" * n, 9)
        if len(blob) % 4 == 0:
            page = np.frombuffer(blob, dtype=np.float32)
            if np.isfinite(page).all():
                return page
    raise AssertionError("no lookalike found")


def test_chunk_compression_flag_not_sniffed():
    """An uncompressed page whose raw bytes parse as zlib must pass
    through bit-identically: the explicit per-chunk flag, not a
    trial-decompress, decides decoding."""
    page = _zlib_lookalike_page()
    # sanity: the old sniffing heuristic WOULD have decompressed this
    zlib.decompress(page.tobytes())
    d = LayerDelta(layer="l1/kernel", shape=(page.size, 1), dtype="float32",
                   indices=np.array([0], np.int64), chunks=[page.tobytes()],
                   chunk_elems=page.size, chunk_compressed=[False])
    dense = delta_lib.delta_to_dense(d).reshape(-1)
    np.testing.assert_array_equal(dense, page)

    # and through the server-side masking path (2-D shape, matching tier)
    packet = UpdatePacket(model="m", from_version=1, to_version=2, deltas=[d])
    lo = float(np.nanpercentile(np.abs(page[np.isfinite(page)]), 50))
    tier = LicenseTier(name="free", masks={"l1": ((lo, np.inf),)})
    masked = _mask_packet(packet, tier).deltas[0]
    assert masked.chunk_compressed == [False]
    out = np.frombuffer(masked.chunks[0], dtype=np.float32)
    mag = np.abs(page)
    banned = mag >= lo
    assert banned.any() and (~banned).any()
    assert (out[banned] == 0).all()
    np.testing.assert_array_equal(out[~banned], page[~banned])

    # compressed pages still round-trip under their explicit flag
    dz = LayerDelta(layer="l1/kernel", shape=(page.size, 1), dtype="float32",
                    indices=np.array([0], np.int64),
                    chunks=[zlib.compress(page.tobytes(), 1)],
                    chunk_elems=page.size, chunk_compressed=[True])
    np.testing.assert_array_equal(delta_lib.delta_to_dense(dz).reshape(-1),
                                  page)


def test_chunk_fetch_cursor_matches_blocking_pull():
    """Applying every fetched part in order == applying handle_update's
    whole packet; the session is byte-metered and logged once."""
    store = WeightStore(":memory:", row_limit=8, chunk_elems=4)
    store.register_model("m", "mlp")
    server = LicenseServer(store)
    p = {"big/kernel": np.arange(32, dtype=np.float32).reshape(8, 4),
         "small/kernel": np.ones((2, 3), np.float32)}
    v1 = server.publish("m", p)
    client = EdgeClient("m", {k: np.zeros_like(v) for k, v in p.items()})
    client.request_update(server)
    ref = EdgeClient("m", {k: np.zeros_like(v) for k, v in p.items()})
    ref.request_update(server)               # same from_version as client

    p2 = {k: v.copy() for k, v in p.items()}
    p2["big/kernel"][0] += 1.0
    p2["small/kernel"][1, 1] = 7.0
    server.publish("m", p2, parent=v1)

    cursor = server.open_update("m", client.version, "full")
    staged = client.params
    fetches = 0
    while True:
        parts = server.fetch_update(cursor, max_bytes=24)
        if not parts:
            break
        fetches += 1
        pk = UpdatePacket(model="m", from_version=client.version,
                          to_version=cursor.to_version, deltas=parts)
        staged = delta_lib.apply_packet(staged, pk, donate=True)
    assert fetches > 1                       # actually incremental
    assert cursor.fetched_bytes == cursor.total_bytes

    ref.request_update(server)
    for k in p:
        np.testing.assert_array_equal(staged[k], ref.params[k])
    # exactly one log entry for the whole cursor session, byte-identical
    # to what the blocking handle_update pull logs
    sessions = [l for l in server.log if l.from_version == client.version]
    assert len(sessions) == 2                # cursor drain + ref's pull
    assert sessions[0].bytes_sent == sessions[1].bytes_sent


def test_weightstore_guards_legacy_f32_chunk_encoding(tmp_path):
    """Format 1 stores encoded chunk pages as f32 regardless of layer
    dtype; opening one that actually holds non-f32 chunk layers must
    refuse rather than decode garbage, while f32-only stores migrate."""
    path = str(tmp_path / "legacy_f16.db")
    store = WeightStore(path, row_limit=8, chunk_elems=4)
    store.register_model("m", "mlp")
    store.commit("m", {"l1/kernel": np.ones((8, 4), np.float16)})
    store.conn.execute("PRAGMA user_version=0")    # masquerade as format 1
    store.conn.commit()
    store.close()
    with pytest.raises(RuntimeError, match="format 1"):
        WeightStore(path)

    path = str(tmp_path / "legacy_f32.db")
    store = WeightStore(path, row_limit=8, chunk_elems=4)
    store.register_model("m", "mlp")
    store.commit("m", {"l1/kernel": np.ones((8, 4), np.float32)})
    store.conn.execute("PRAGMA user_version=0")
    store.conn.commit()
    store.close()
    store = WeightStore(path)                      # f32-only: stamped forward
    ver, = store.conn.execute("PRAGMA user_version").fetchone()
    assert ver == WeightStore._FORMAT_VERSION
    store.close()


def test_delta_apply_inplace_matches_copy():
    from repro.kernels import ops

    buf = np.arange(8192, dtype=np.float32)
    idx = np.array([0, 5000, 8191])
    val = np.array([9.0, -1.0, 3.5], np.float32)
    import jax.numpy as jnp

    a = np.asarray(ops.delta_apply(jnp.asarray(buf), jnp.asarray(idx),
                                   jnp.asarray(val)))
    b = np.asarray(ops.delta_apply(jnp.asarray(buf), jnp.asarray(idx),
                                   jnp.asarray(val), donate=True))
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ staged gateway
@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _server_with(params, tier_masks=((0.0, 0.004),)):
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": tuple(tier_masks)}))
    return server


def _boot(cfg, server, params, **kw):
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", 16)
    return LicensedGateway.from_server(cfg, server, "lm", template, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


@pytest.mark.parametrize("quantized", [False, True])
def test_midstream_staged_sync_equivalence(setup, quantized):
    """Requests in flight across a staged sync produce bit-identical
    tokens to an update-free run; admissions after the flip serve the
    new version through a prewarmed view."""
    cfg, params = setup
    server = _server_with(params)

    # update-free reference run
    ref = _boot(cfg, server, params, quantized=quantized)
    a0 = ref.submit(_prompt(1), license="free", max_new_tokens=12)
    b0 = ref.submit(_prompt(2), license="free", max_new_tokens=12)
    ref.run()

    gw = _boot(cfg, server, params, quantized=quantized)
    a = gw.submit(_prompt(1), license="free", max_new_tokens=12)
    b = gw.submit(_prompt(2), license="free", max_new_tokens=12)
    gw.step()                                # prefill: a, b in flight
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    # pace the staging so the flip lands while a and b are still decoding
    # (the prewarm needs a hot tier to warm)
    assert gw.begin_sync(max_step_bytes=4 << 20,
                         requant_layers_per_step=8) is True
    flip_checked = False
    for _ in range(10_000):
        if not (gw.sync_active or gw.scheduler.waiting
                or gw.scheduler.running):
            break
        gw.step()
        if not gw.sync_active and not flip_checked:
            flip_checked = True
            v2 = gw.version
            assert v2 == gw._client.version != 1
            # hot tier prewarmed at the new version BEFORE any admission
            assert ("free", v2) in gw.views
    assert flip_checked, "staged sync never flipped"
    assert a.state == b.state == RequestState.DONE
    assert (a.version, b.version) == (1, 1)  # pinned across the flip
    assert a.out_tokens == a0.out_tokens
    assert b.out_tokens == b0.out_tokens

    st = gw.metrics()["staged_update"]
    assert st["flips"] == 1 and st["views_prewarmed"] >= 1
    if quantized:
        # incremental path: only touched layers requantized, and the
        # rebuilt store matches a from-scratch full requantize exactly
        from repro.serving.quantized import quantize_serving_params

        assert st["layers_requantized"] == st["layers_touched"] > 0
        full = quantize_serving_params(gw._client.params)
        for got, want in zip(jax.tree_util.tree_leaves(gw._weights[v2]),
                             jax.tree_util.tree_leaves(full)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # the prewarmed view serves the first new-version admission (no miss)
    misses = gw.views.misses
    r = gw.submit(_prompt(3), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE and r.version == v2
    assert gw.views.misses == misses


def test_atomic_tier_and_version_flip(setup):
    """A tier redefinition published together with a version bump goes
    live in the same stager step as the new weights: at every scheduler
    step boundary the gateway is either fully old or fully new."""
    cfg, params = setup
    old_masks = ((0.0, 0.004),)
    new_masks = ((0.0, 0.01),)
    server = _server_with(params, old_masks)
    gw = _boot(cfg, server, params)
    r = gw.submit(_prompt(1), license="free", max_new_tokens=4)
    gw.run()
    assert r.state == RequestState.DONE
    assert gw.tiers["free"].masks == {"*": old_masks}

    # same server commit: new production version AND redefined tier
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": new_masks}))

    assert gw.begin_sync(max_step_bytes=4096) is True
    saw_staging_steps = 0
    while gw.sync_active:
        gw.step()
        tier_new = gw.tiers["free"].masks == {"*": new_masks}
        version_new = gw.version != 1
        # the forbidden intermediate states: (new tier, old version) is
        # the pre-fix sync() bug; (old tier, new version) its mirror
        assert tier_new == version_new, (tier_new, version_new)
        if gw.sync_active:
            saw_staging_steps += 1
            # mid-staging admissions pin the fully-old state
            assert gw.submit(_prompt(5), license="free",
                             max_new_tokens=1).version == 1
    assert saw_staging_steps > 1             # the flip was actually staged
    assert gw.tiers["free"].masks == {"*": new_masks}
    gw.run()

    # functional: a post-flip admission behaves exactly like a fresh pod
    # booted from the server's new state
    fresh = _boot(cfg, server, params)
    assert fresh.version == gw.version
    want = fresh.submit(_prompt(9), license="free", max_new_tokens=4)
    fresh.run()
    got = gw.submit(_prompt(9), license="free", max_new_tokens=4)
    gw.run()
    assert got.out_tokens == want.out_tokens


def test_stager_bounded_bytes_per_step(setup):
    """No stager step applies more than max_step_bytes (+ one indivisible
    chunk page), no matter the update size — the bound the decode-stall
    benchmark rides on."""
    cfg, params = setup
    store = WeightStore(":memory:", row_limit=2048, chunk_elems=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": ((0.0, 0.004),)}))
    gw = _boot(cfg, server, params)
    # touch ONE whole large (chunk-mode) layer: the per-step bound must
    # hold however big a single layer's delta is
    from repro.core.pytree_io import flatten_params

    flat = flatten_params(params)
    big = max(flat, key=lambda k: flat[k].size)
    assert flat[big].size > 2048                 # really chunk-mode
    newp = {k: (v * 1.01 if k == big else v) for k, v in flat.items()}
    server.publish("lm", newp, tag="v2")

    budget = 16 << 10
    # one indivisible page of slack, plus zlib can exceed raw size on
    # incompressible data (+8 index bytes per page)
    page_bytes = 2048 * 4 + 1024
    assert gw.begin_sync(max_step_bytes=budget) is True
    while gw.sync_active:
        gw.sync_step()
    st = gw.metrics()["staged_update"]
    assert st["flips"] == 1
    assert st["bytes_applied"] > budget          # genuinely incremental
    assert st["max_step_bytes_applied"] <= budget + page_bytes
    assert st["steps"] > st["bytes_applied"] // (budget + page_bytes)


def test_redefined_tier_in_flight_at_flip_rejects_admissions(setup):
    """The deferred window is unobservable: when the flip lands while
    the redefined tier still has requests decoding, the redefinition
    defers (pinning holds) and NEW admissions to that tier are refused
    until it drains — nothing is ever served under (old masks, new
    version)."""
    cfg, params = setup
    old_masks = ((0.0, 0.004),)
    new_masks = ((0.0, 0.01),)
    server = _server_with(params, old_masks)
    gw = _boot(cfg, server, params)
    warm = gw.submit(_prompt(0), license="free", max_new_tokens=1)
    gw.run()
    assert warm.state == RequestState.DONE

    # a long request holds the tier in flight across the whole staging
    r = gw.submit(_prompt(1), license="free", max_new_tokens=16)
    gw.step()
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": new_masks}))
    assert gw.begin_sync(max_step_bytes=8 << 20) is True
    while gw.sync_active:
        gw.step()
    v2 = gw.version
    assert v2 != 1 and r.state == RequestState.RUNNING
    # deferred: old masks still in the table, but the tier refuses new
    # admissions rather than serving them under (old masks, v2)
    assert gw.tiers["free"].masks == {"*": old_masks}
    rej = gw.submit(_prompt(2), license="free", max_new_tokens=1)
    assert rej.state == RequestState.REJECTED and "redefined" in rej.error
    gw.run()                                 # r drains -> redefinition lands
    assert r.state == RequestState.DONE and r.version == 1
    assert gw.tiers["free"].masks == {"*": new_masks}
    ok = gw.submit(_prompt(3), license="free", max_new_tokens=1)
    assert ok.state != RequestState.REJECTED and ok.version == v2
    gw.run()
    assert ok.state == RequestState.DONE


def test_failed_staging_aborts_clean(setup):
    """A stage step that raises must tear the session down (active ->
    False, staging version unregistered) instead of wedging the serving
    loop; the gateway keeps serving and can begin a fresh sync."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    r = gw.submit(_prompt(1), license="free", max_new_tokens=4)
    gw.run()
    assert r.state == RequestState.DONE

    # v2's delta names a layer the gateway's client never had
    from repro.core.pytree_io import flatten_params

    flat = flatten_params(params)
    newp = dict(flat)
    newp["rogue/kernel"] = np.ones((4, 4), np.float32)
    server.publish("lm", newp, tag="v2")

    assert gw.begin_sync(max_step_bytes=1 << 30) is True
    with pytest.raises(KeyError, match="rogue/kernel"):
        while gw.sync_active:
            gw.step()
    assert not gw.sync_active
    assert gw.version == 1 and gw._staging_version is None
    assert 2 not in gw._weights
    assert gw.metrics()["staged_update"]["phase"] == "failed"
    # the gateway still serves, and a fresh sync can be attempted
    r2 = gw.submit(_prompt(2), license="free", max_new_tokens=2)
    gw.run()
    assert r2.state == RequestState.DONE and r2.version == 1
    assert gw.begin_sync() is True           # fresh cursor, same failure
    with pytest.raises(KeyError):
        gw.sync_step()


def test_background_fetch_runs_on_worker_thread(setup):
    """The wire transfer (fetch_update) happens on the stager's worker
    thread, never the serving thread; the flip still lands and the
    result is identical to a fresh boot from the server."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    warm = gw.submit(_prompt(0), license="free", max_new_tokens=1)
    gw.run()
    assert warm.state == RequestState.DONE
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    fetch_threads = []
    orig = server.fetch_update

    def spy(cursor, max_bytes):
        fetch_threads.append(threading.current_thread().name)
        return orig(cursor, max_bytes)

    server.fetch_update = spy
    assert gw.begin_sync(max_step_bytes=16 << 10) is True
    assert gw.metrics()["staged_update"]["background_fetch"] is True
    while gw.sync_active:
        gw.sync_step()
    del server.fetch_update
    assert len(fetch_threads) > 1                # genuinely incremental
    assert all(t == "update-stager-fetch" for t in fetch_threads)
    assert gw.version == gw._client.version != 1

    fresh = _boot(cfg, server, params)
    want = fresh.submit(_prompt(7), license="free", max_new_tokens=4)
    fresh.run()
    got = gw.submit(_prompt(7), license="free", max_new_tokens=4)
    gw.run()
    assert got.out_tokens == want.out_tokens


def test_background_fetch_off_equivalence(setup):
    """``background_fetch=False`` (synchronous wire transfer) stages the
    exact same bytes and lands the exact same weights."""
    cfg, params = setup

    def _synced(background_fetch):
        server = _server_with(params)
        gw = _boot(cfg, server, params)
        newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01,
                                      params)
        server.publish("lm", newp, tag="v2")
        assert gw.begin_sync(max_step_bytes=16 << 10,
                             background_fetch=background_fetch) is True
        while gw.sync_active:
            gw.sync_step()
        return gw

    a = _synced(True)
    b = _synced(False)
    sa, sb = a.metrics()["staged_update"], b.metrics()["staged_update"]
    assert sa["bytes_applied"] == sb["bytes_applied"] > 0
    assert sa["parts_applied"] == sb["parts_applied"]
    assert (sa["background_fetch"], sb["background_fetch"]) == (True, False)
    for x, y in zip(jax.tree_util.tree_leaves(a._client.params),
                    jax.tree_util.tree_leaves(b._client.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_background_fetch_worker_exception_aborts(setup):
    """A wire failure on the WORKER thread surfaces on the serving
    thread and runs the standard abort teardown: session failed, staging
    version unregistered, gateway still serving, fresh sync possible."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    orig = server.fetch_update

    def broken(cursor, max_bytes):
        raise ConnectionError("wire dropped")

    server.fetch_update = broken
    assert gw.begin_sync(max_step_bytes=16 << 10) is True
    with pytest.raises(ConnectionError, match="wire dropped"):
        while gw.sync_active:
            gw.sync_step()
    assert not gw.sync_active
    assert gw.version == 1 and gw._staging_version is None
    assert gw.metrics()["staged_update"]["phase"] == "failed"
    assert gw._stager._fetch_thread is None      # worker joined

    # wire restored: serving never stopped, and a fresh sync lands
    server.fetch_update = orig
    r = gw.submit(_prompt(2), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE and r.version == 1
    assert gw.sync() is True
    assert gw.version == gw._client.version != 1


def test_sync_already_current_refreshes_tiers_only(setup):
    """Blocking-sync parity through the stager: no new version -> False,
    and a tier-only redefinition still lands immediately."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE
    log_before = len(server.log)
    assert gw.sync() is False
    stricter = LicenseTier(name="free", masks={"*": ((0.0, 0.02),)})
    server.publish_tier("lm", stricter)
    assert gw.sync() is False                    # no weights to stage...
    assert gw.tiers["free"].masks == stricter.masks   # ...tiers applied
    # no-op polls use the cheap production_version probe: no delta query,
    # no empty sessions accumulating in the audit log
    assert len(server.log) == log_before


# ----------------------------------------------------- fault-tolerance edges
def test_leaked_fetch_worker_fails_sync_instead_of_flipping(setup):
    """A worker still alive after the join timeout must FAIL the sync —
    the old code ignored the timeout and flipped with a live thread
    still writing cursor/staging state — and the leak must be visible
    in stats()."""
    import time as _time

    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    assert gw.begin_sync(max_step_bytes=1 << 30, join_timeout_s=0.05) is True
    st = gw._stager
    # swap in a stubborn worker that ignores the stop signal; the real
    # worker finishes its single batch and exits on its own
    real = st._fetch_thread
    gate = threading.Event()
    stubborn = threading.Thread(target=gate.wait, daemon=True)
    stubborn.start()
    for _ in range(100):                      # let the real worker finish
        if not real.is_alive():
            break
        _time.sleep(0.05)
    assert not real.is_alive()
    st._fetch_thread = stubborn

    with pytest.raises(RuntimeError, match="refusing to flip"):
        while gw.sync_active:
            gw.sync_step()
    gate.set()
    assert not gw.sync_active
    assert st.stats()["fetch_workers_leaked"] == 1
    assert gw.version == 1 and gw._staging_version is None
    assert 2 not in gw._weights                # nothing half-flipped
    # the gateway still serves and a clean retry lands
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE
    assert gw.sync() is True and gw.version == 2


def test_backpressure_stalled_consumer_neither_drops_nor_spins(setup):
    """With the consumer stalled, the bounded queue must hold the worker
    at ~fetch_depth batches ahead (no unbounded fetching, no dropped
    parts); an abort while the queue is full must still join the
    worker."""
    import time as _time

    cfg, params = setup

    def _chunked_server():
        store = WeightStore(":memory:", row_limit=2048, chunk_elems=2048)
        server = LicenseServer(store)
        server.publish("lm", params, tag="v1")
        server.publish_tier("lm", LicenseTier(name="free",
                                              masks={"*": ((0.0, 0.004),)}))
        return server

    def _publish_v2(server):
        newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01,
                                      params)
        server.publish("lm", newp, tag="v2")

    server = _chunked_server()
    gw = _boot(cfg, server, params)
    _publish_v2(server)
    calls = []
    orig = server.fetch_update

    def spy(cursor, max_bytes):
        calls.append(1)
        return orig(cursor, max_bytes)

    server.fetch_update = spy
    assert gw.begin_sync(max_step_bytes=16 << 10, fetch_depth=1) is True
    # stalled consumer: no sync_step for a while — the worker must park
    # on the full queue, not keep fetching (depth + one batch in hand)
    _time.sleep(0.6)
    assert len(calls) <= 3
    # consumer resumes: every part arrives exactly once, the sync lands
    while gw.sync_active:
        gw.sync_step()
    del server.fetch_update
    st = gw.metrics()["staged_update"]
    assert st["flips"] == 1 and st["fetch_workers_leaked"] == 0
    assert gw.version == gw._client.version == 2
    fresh = _boot(cfg, server, params)           # no dropped parts: weights
    for x, y in zip(jax.tree_util.tree_leaves(gw._client.params),
                    jax.tree_util.tree_leaves(fresh._client.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # abort with the queue full must still join the worker cleanly
    server2 = _chunked_server()
    gw2 = _boot(cfg, server2, params)
    _publish_v2(server2)
    assert gw2.begin_sync(max_step_bytes=16 << 10, fetch_depth=1) is True
    st2 = gw2._stager
    _time.sleep(0.3)                             # queue fills, worker parked
    st2.abort()
    assert st2._fetch_thread is None
    assert st2.stats()["fetch_workers_leaked"] == 0
    assert gw2.version == 1 and gw2._staging_version is None


def test_abort_mid_prewarm_leaves_registry_clean(setup):
    """Aborting after the staging version (and possibly its views) are
    pre-registered must leave the view cache and version registry
    exactly as before the sync — the _gc_versions invariant."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    # a long request keeps the "free" tier hot so prewarm has work
    r = gw.submit(_prompt(1), license="free", max_new_tokens=16)
    gw.step()
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    assert gw.begin_sync(max_step_bytes=1 << 20) is True
    while gw.sync_active and gw._stager.phase != "prewarm":
        gw.sync_step()
    assert gw._stager.phase == "prewarm"
    assert gw._staging_version == 2 and 2 in gw._weights
    gw._stager.abort()

    assert gw._staging_version is None
    assert 2 not in gw._weights
    assert ("free", 2) not in gw.views
    gw._gc_versions()                            # invariant holds post-abort
    assert set(gw._weights) == gw.scheduler.pinned_versions() | {1}
    gw.run()
    assert r.state == RequestState.DONE and r.version == 1

    # a clean re-begin lands with exactly one version_flip ever recorded
    assert gw.sync() is True
    assert gw.version == 2
    assert len(gw.audit.events("version_flip")) == 1
    assert len(gw.audit.events("sync_abort")) == 1


def test_abort_then_retry_of_quarantined_version(setup):
    """abort → quarantine → begin refuses → clear_quarantine → clean
    re-sync; at every stage the view cache and version registry hold the
    no-staged-version-leak invariant."""
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params, quarantine_after=1)
    warm = gw.submit(_prompt(0), license="free", max_new_tokens=1)
    gw.run()
    assert warm.state == RequestState.DONE
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    assert gw.begin_sync(max_step_bytes=16 << 10) is True
    gw.sync_step()                               # some parts staged
    gw._stager.abort()
    assert gw.quarantined_versions == {2}
    assert gw._staging_version is None and 2 not in gw._weights
    assert ("free", 2) not in gw.views           # no staged-view leak
    gw._gc_versions()
    assert set(gw._weights) == gw.scheduler.pinned_versions() | {1}

    assert gw.begin_sync() is False              # quarantined: refuses
    assert gw._staging_version is None and 2 not in gw._weights

    gw.clear_quarantine(2)
    assert gw.sync() is True                     # operator override: lands
    assert gw.version == gw._client.version == 2
    assert len(gw.audit.events("version_flip")) == 1
    r = gw.submit(_prompt(2), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE and r.version == 2
