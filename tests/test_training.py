"""Training substrate: optimizer math, convergence, versioned checkpoints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.weightstore import WeightStore
from repro.data import LMDataConfig, classification_data, lm_batches
from repro.training import (
    OptimizerConfig,
    apply_updates,
    init_state,
    mlp_accuracy,
    train_loop,
    train_mlp,
)
from repro.configs.paper_mlp import TABLE1_A


def test_adamw_decreases_quadratic():
    """AdamW drives a quadratic toward its minimum."""
    params = {"w": jnp.ones((4, 4)) * 5.0}
    ocfg = OptimizerConfig(lr=0.5, weight_decay=0.0, warmup_steps=0,
                           total_steps=100, min_lr_ratio=1.0)
    state = init_state(params)
    for _ in range(60):
        grads = {"w": params["w"]}  # d/dw of 0.5 w^2
        params, state, m = apply_updates(params, grads, state, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0
    assert int(state.step) == 60


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((8,))}
    ocfg = OptimizerConfig(lr=1e-2, grad_clip=1.0, warmup_steps=0)
    state = init_state(params)
    _, _, metrics = apply_updates(params, {"w": jnp.full((8,), 1e6)}, state, ocfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_no_weight_decay_on_norms():
    params = {"norm_scale": jnp.ones((8,)), "kernel": jnp.ones((8, 8))}
    ocfg = OptimizerConfig(lr=1e-2, weight_decay=10.0, warmup_steps=0)
    state = init_state(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new, _, _ = apply_updates(params, zero_g, state, ocfg)
    np.testing.assert_allclose(np.asarray(new["norm_scale"]), 1.0)   # untouched
    assert float(new["kernel"][0, 0]) < 1.0                          # decayed


def test_mlp_trains_to_high_accuracy():
    x, y = classification_data(4000, TABLE1_A.in_dim, TABLE1_A.num_classes, seed=0)
    params = train_mlp(TABLE1_A, x[:3000], y[:3000], steps=400)
    acc = mlp_accuracy(params, x[3000:], y[3000:])
    assert acc > 0.9


@pytest.mark.slow
def test_lm_loss_decreases_markedly():
    """~100-step training on structured data must reduce loss (end-to-end)."""
    cfg = smoke_variant(get_config("qwen2.5-3b")).replace(vocab_size=512)
    data = lm_batches(LMDataConfig(vocab_size=512, seq_len=64, batch_size=8))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=120)
    _, hist = train_loop(cfg, ocfg, data, 120, log_every=20, log_fn=lambda s: None)
    assert hist["loss"][-1] < hist["loss"][0] - 0.5


def test_checkpoints_are_delta_committed():
    cfg = smoke_variant(get_config("mamba2-130m")).replace(vocab_size=256)
    data = lm_batches(LMDataConfig(vocab_size=256, seq_len=32, batch_size=4))
    store = WeightStore(":memory:")
    store.register_model(cfg.name, cfg.arch_type)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=6)
    params, _ = train_loop(cfg, ocfg, data, 6, store=store, store_model=cfg.name,
                           checkpoint_every=3, log_fn=lambda s: None)
    hist = store.history(cfg.name)
    assert len(hist) == 2
    # reconstruct latest checkpoint and compare to final params
    from repro.core import flatten_params

    out = store.checkout(cfg.name)
    want = flatten_params(jax.device_get(params))
    for k, v in want.items():
        np.testing.assert_allclose(out[k], np.asarray(v, np.float32),
                                   rtol=1e-5, atol=1e-6)
