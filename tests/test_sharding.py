"""Partition-rule unit tests (distribution/sharding.py) on a tiny mesh."""
import jax
from jax.sharding import PartitionSpec as P

from repro.distribution import sharding as shd

# 1 real CPU device: build a 1x1 mesh with the production axis names so
# the divisibility logic exercises the same code paths
MESH = jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in so rules can be tested against a 16x16 mesh
    without 256 devices."""

    def __init__(self, shape):
        self.shape = shape


M16 = FakeMesh({"data": 16, "model": 16})


def test_embed_shards_vocab():
    assert shd.param_spec("embed/tok", (51200, 768), M16) == P("model", None)


def test_col_parallel_projections():
    assert shd.param_spec("units/b0/mixer/wq", (36, 2048, 2048), M16) == \
        P(None, None, "model")
    assert shd.param_spec("units/b0/ffn/w_up", (36, 2048, 11008), M16) == \
        P(None, None, "model")


def test_row_parallel_projections():
    assert shd.param_spec("units/b0/mixer/wo", (36, 2048, 2048), M16) == \
        P(None, "model", None)
    assert shd.param_spec("units/b0/ffn/w_down", (36, 11008, 2048), M16) == \
        P(None, "model", None)


def test_experts_shard_expert_dim():
    assert shd.param_spec("units/b0/ffn/experts/w_gate", (27, 64, 2048, 1408),
                          M16) == P(None, "model", None, None)


def _replicated(spec) -> bool:
    return all(a is None for a in spec)


def test_norms_and_dynamics_replicated():
    for name in ("units/b0/norm1/norm_scale", "units/b0/mixer/A_log",
                 "units/b0/mixer/conv_w", "units/b0/ffn/router"):
        spec = shd.param_spec(name, (36, 768), M16)
        assert _replicated(spec), (name, spec)


def test_indivisible_dims_fall_back():
    # vocab 50280 % 16 != 0 -> replicated rather than invalid
    assert _replicated(shd.param_spec("embed/tok", (50280, 768), M16))


def test_codes_inherit_parent_scale_replicated():
    assert shd.param_spec("units/b0/mixer/wq/codes", (36, 2048, 2048), M16) == \
        P(None, None, "model")
    assert shd.param_spec("units/b0/mixer/wq/scale", (36, 1, 2048), M16) == P()


def test_zero1_opt_spec_adds_data_axis():
    base = shd.param_spec("units/b0/mixer/wq", (36, 2048, 2048), M16)
    z = shd.opt_spec(base, (36, 2048, 2048), M16)
    assert "data" in [a for a in z if a]


def test_fsdp_spec_shards_largest_free_dim():
    base = shd.param_spec("units/b0/ffn/w_up", (36, 2048, 11008), M16)
    f = shd.fsdp_spec(base, (36, 2048, 11008), M16)
    assert f == P(None, "data", "model")


def test_batch_spec_rules():
    m_multi = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.batch_spec(256, M16) == "data"
    assert shd.batch_spec(256, m_multi) == ("pod", "data")
    assert shd.batch_spec(1, M16) is None


def test_ssm_nondivisible_heads_replicate_mixer():
    from repro.configs import get_config

    cfg = get_config("mamba2-130m")  # 24 SSD heads, 24 % 16 != 0
    kws = shd.tp_replicate_keywords(cfg, M16)
    assert "in_proj" in kws and "out_proj" in kws


def test_kv_replication_rule():
    from repro.configs import get_config

    kws = shd.tp_replicate_keywords(get_config("qwen2.5-3b"), M16)  # kv=2
    assert "wk" in kws and "wv" in kws
    kws32 = shd.tp_replicate_keywords(get_config("musicgen-large"), M16)  # kv=32
    assert "wk" not in kws32
