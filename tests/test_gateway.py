"""Licensed serving gateway: batching invariants, view cache, equivalence."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import (GatewayRequest, LicensedGateway, Request,
                           RequestState, Scheduler, ServingEngine)

MAX_PROMPT = 8
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {
        "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
        "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
    }
    return cfg, params, tiers


def _gateway(setup, **kw):
    cfg, params, tiers = setup
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    return LicensedGateway(cfg, params, tiers=tiers, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


# ------------------------------------------------------------- scheduling
def test_micro_batches_are_tier_homogeneous(setup):
    gw = _gateway(setup, max_batch=2)
    reqs = [gw.submit(_prompt(i), license=lic, max_new_tokens=3 + i % 3)
            for i, lic in enumerate(
                ["full", "free", "pro", "free", "full", "pro", "free"])]
    gw.run()
    assert all(r.state == RequestState.DONE for r in reqs)
    assert len(gw.trace) > 0
    # the invariant the masked-view batching rests on: one (tier, version)
    # per micro-batch -- recorded per action by the gateway
    for kind, tier, version, n in gw.trace:
        assert kind in ("prefill", "decode")
        assert 1 <= n <= 2
    # requests in each completed batch got exactly their token budget
    for r in reqs:
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < gw.cfg.padded_vocab for t in r.out_tokens)


def test_continuous_refill_more_requests_than_lanes(setup):
    gw = _gateway(setup, max_batch=2)
    reqs = [gw.submit(_prompt(i), license="full", max_new_tokens=2 + 2 * (i % 2))
            for i in range(5)]
    gw.run()
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    # with 2 lanes and 5 requests, admission must interleave with decode:
    # some prefill happens after the first decode
    kinds = [k for k, *_ in gw.trace]
    first_decode = kinds.index("decode")
    assert "prefill" in kinds[first_decode:]


def test_scheduler_prefill_groups_same_key_only():
    s = Scheduler(num_lanes=4, max_batch=4)
    for i, lic in enumerate(["free", "free", "full", "free"]):
        r = GatewayRequest(prompt=np.zeros(4, np.int32), license=lic)
        r.version = 1
        s.submit(r)
    act = s.next_action()
    assert act.kind == "prefill"
    assert {r.license for r in act.requests} == {"free"}
    assert len(act.requests) == 3  # skips the interleaved "full" request


def test_admission_rejects_unknown_tier_and_long_prompt(setup):
    gw = _gateway(setup)
    r = gw.submit(_prompt(0), license="enterprise")
    assert r.state == RequestState.REJECTED and "enterprise" in r.error
    r = gw.submit(np.zeros(MAX_PROMPT + 1, np.int32), license="full")
    assert r.state == RequestState.REJECTED
    assert gw.stats["rejected"] == 2 and gw.stats["admitted"] == 0


# -------------------------------------------------------------- view cache
def test_view_cache_hits_and_invalidation_on_version_bump(setup):
    cfg, params, tiers = setup
    gw = _gateway(setup)
    for i in range(3):
        gw.submit(_prompt(i), license="free", max_new_tokens=4)
    gw.run()
    st = gw.views.stats()
    assert st["misses"] == 1                      # one build per (tier, version)
    assert st["hits"] >= 2                        # amortized across the stream
    assert ("free", 1) in gw.views

    # version bump: new admissions pin v2; v1 views die once v1 drains
    v2 = gw.update_weights(jax.tree_util.tree_map(lambda x: x * 1.5, params))
    assert v2 == 2
    assert ("free", 1) not in gw.views            # nothing pins v1 anymore
    assert gw.views.stats()["invalidations"] >= 1
    r = gw.submit(_prompt(9), license="free", max_new_tokens=2)
    assert r.version == v2
    gw.run()
    assert ("free", v2) in gw.views
    assert 1 not in gw._weights                   # stale base weights dropped

    # overwriting a live version must also drop its cached views
    gw.update_weights(params, version=v2)
    assert ("free", v2) not in gw.views


def test_in_flight_requests_keep_pinned_version(setup):
    cfg, params, tiers = setup
    gw = _gateway(setup)
    a = gw.submit(_prompt(0), license="free", max_new_tokens=3)
    assert gw.step().kind == "prefill"            # a is running under v1
    gw.update_weights(jax.tree_util.tree_map(lambda x: x * 1.5, params))
    b = gw.submit(_prompt(0), license="free", max_new_tokens=3)
    gw.run()
    assert (a.version, b.version) == (1, 2)
    assert a.state == b.state == RequestState.DONE
    # both versions' views were materialized -> two misses for "free"
    assert gw.views.misses >= 2
    # with the same prompt, v2 (scaled weights) may decode differently;
    # the invariant is that *a* was never re-masked mid-flight
    assert 1 not in gw._weights                   # dropped after a drained


# ------------------------------------------------------------- equivalence
def test_gateway_decode_matches_single_stream_engine(setup):
    cfg, params, tiers = setup
    engine = ServingEngine(cfg, params, tiers=tiers)
    gw = _gateway(setup)
    prompt = _prompt(7)
    for lic in ("full", "free"):
        er = Request(prompt=prompt.copy(), max_new_tokens=MAX_NEW, license=lic)
        engine.generate([er])
        gr = gw.submit(prompt, license=lic, max_new_tokens=MAX_NEW)
        gw.run()
        assert gr.out_tokens == er.out_tokens, lic


def test_quantized_gateway_one_store_many_tiers(setup):
    cfg, params, tiers = setup
    gw = _gateway(setup, quantized=True)
    r1 = gw.submit(_prompt(3), license="full", max_new_tokens=3)
    r2 = gw.submit(_prompt(3), license="free", max_new_tokens=3)
    gw.run()
    assert len(r1.out_tokens) == len(r2.out_tokens) == 3
    # one int8 store: both views share the SAME params object
    p_full, _ = gw.view_for("full")
    p_free, li_free = gw.view_for("free")
    assert p_full is p_free
    assert li_free is not None


def test_materialized_int8_views_match_in_scan_dequant(setup):
    cfg, params, tiers = setup
    prompt = _prompt(5)
    outs = []
    for mat in (False, True):
        gw = _gateway(setup, quantized=True, materialize_int8_views=mat)
        r = gw.submit(prompt, license="free", max_new_tokens=3)
        gw.run()
        outs.append(r.out_tokens)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------- protocol
def test_gateway_from_license_server(setup):
    from repro.core.protocol import LicenseServer
    from repro.core.weightstore import WeightStore

    cfg, params, tiers = setup
    params = jax.device_get(params)
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", tiers["free"])
    assert server.has_tier("lm", "free") and not server.has_tier("lm", "nope")

    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    gw = LicensedGateway.from_server(cfg, server, "lm", template,
                                     max_batch=2, max_prompt=MAX_PROMPT,
                                     max_new_cap=3)
    # tier resolved from the server's accuracy table at admission
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    assert r.state != RequestState.REJECTED
    gw.run()
    assert r.state == RequestState.DONE

    assert gw.sync() is False                     # already at production
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    assert gw.sync() is True
    r2 = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    assert r2.version == gw.version and r2.version != r.version
    gw.run()
    assert r2.state == RequestState.DONE

    # a tier redefined server-side must replace the memoized one on sync
    stricter = LicenseTier(name="free", masks={"*": ((0.0, 0.01),)})
    server.publish_tier("lm", stricter)
    gw.sync()
    assert gw.tiers["free"].masks == stricter.masks
    assert ("free", gw.version) not in gw.views   # stale view dropped

    # ... but never mid-flight: with a 'free' request running, the next
    # redefinition is deferred until that request drains
    relaxed = LicenseTier(name="free", masks={"*": ((0.0, 0.001),)})
    server.publish_tier("lm", relaxed)
    a = gw.submit(_prompt(4), license="free", max_new_tokens=2)
    assert gw.step().kind == "prefill"            # a in flight under stricter
    gw.sync()
    assert gw.tiers["free"].masks == stricter.masks   # unchanged while pinned
    gw.run()                                      # a drains -> update applies
    assert a.state == RequestState.DONE
    assert gw.tiers["free"].masks == relaxed.masks


def test_update_weights_rejects_version_regression(setup):
    cfg, params, tiers = setup
    gw = _gateway(setup)
    gw.update_weights(params)                     # -> v2
    with pytest.raises(ValueError):
        gw.update_weights(params, version=1)
    # the shared padding helper names the offending row on empty prompts
    from repro.serving.engine import right_align

    with pytest.raises(ValueError):
        right_align([np.zeros(0, np.int32)], 4, 1)



def test_engine_gateway_constructor(setup):
    cfg, params, tiers = setup
    engine = ServingEngine(cfg, params, tiers=tiers)
    gw = engine.gateway(max_batch=2, max_prompt=MAX_PROMPT, max_new_cap=2)
    r = gw.submit(_prompt(2), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE
